"""Property-based invariants of the GS resource ledger and the
segmented (handover) transfer planner.

Uses hypothesis through the conftest shim: when hypothesis is not
installed the ``@given`` tests auto-skip (collection never fails); the
CI property job installs hypothesis so they actually execute there.
The property bodies live in plain ``_check_*`` helpers, exercised by a
seeded random sweep as well (``test_invariants_random_sweep``) so the
invariants stay covered even where hypothesis is absent.

Invariants:
  * occupancy never exceeds capacity after ANY sequence of
    ``earliest_fit``-placed reservations;
  * ``earliest_fit`` is monotone in its lower bound, never answers
    before it, and its answer always has a free RB for the whole
    duration;
  * unlimited capacity makes the ledger a no-op (``earliest_fit`` is
    the identity on the lower bound) no matter what was reserved;
  * ``reserve`` -> ``release`` round-trips the ledger to its prior
    occupancy (any release order), and releasing a never-booked
    interval raises;
  * segmented plans conserve the payload bits exactly, serialize their
    legs, alternate stations, stay inside their windows, and never
    transmit through a saturated stretch.
"""
import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.comms import GSResourceLedger, LinkConfig
from repro.core.scheduling import plan_segmented_transfer
from repro.orbits import (
    ConstellationConfig,
    GroundStation,
    VisibilityPredictor,
    WalkerDelta,
)
from repro.orbits.constellation import Satellite

_NUM_STATIONS = 3
_HI = 1e9

_times = st.floats(min_value=0.0, max_value=1e5,
                   allow_nan=False, allow_infinity=False)
_durations = st.floats(min_value=1e-3, max_value=1e4,
                       allow_nan=False, allow_infinity=False)
_requests = st.lists(
    st.tuples(_times, _durations, st.integers(0, _NUM_STATIONS - 1)),
    min_size=1, max_size=30,
)
_caps = st.integers(min_value=1, max_value=4)

_WORLD = None


def _world():
    """Small two-station world, built once (module-lazy, no fixture —
    the hypothesis shim replaces test signatures)."""
    global _WORLD
    if _WORLD is None:
        cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
        walker = WalkerDelta(cfg)
        a = GroundStation()
        b = GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                          name="GS-B")
        pred = VisibilityPredictor(walker, [a, b], horizon_s=24 * 3600.0)
        _WORLD = (cfg, walker, [a, b], pred)
    return _WORLD


# --- property bodies (plain helpers) ------------------------------------------
def _check_capacity_respected(cap, reqs):
    """Placing every request at its earliest_fit start never drives any
    station's occupancy above its capacity, at any event time."""
    led = GSResourceLedger(_NUM_STATIONS, cap)
    for lo, dur, gi in reqs:
        t0 = led.earliest_fit(gi, lo, _HI, dur)
        assert t0 is not None and t0 >= lo
        led.reserve(gi, t0, t0 + dur)
    for gi in range(_NUM_STATIONS):
        s, e = led.reservations(gi)
        if s.size == 0:
            continue
        probes = np.concatenate([s, (s + e) / 2.0, np.maximum(s, e - 1e-9)])
        for t in probes:
            assert led.occupancy(gi, float(t)) <= cap


def _check_earliest_fit_monotone(cap, reqs, lo1, lo2, dur):
    """earliest_fit answers at or after the bound, moves monotonically
    with it, and its answer has a free RB over the whole duration."""
    led = GSResourceLedger(1, cap)
    for lo, d, _gi in reqs:
        led.reserve(0, lo, lo + d)          # arbitrary booking history
    lo_a, lo_b = min(lo1, lo2), max(lo1, lo2)
    f_a = led.earliest_fit(0, lo_a, _HI, dur)
    f_b = led.earliest_fit(0, lo_b, _HI, dur)
    assert f_a is not None and f_b is not None
    assert f_a >= lo_a and f_b >= lo_b
    assert f_a <= f_b                       # monotone in the lower bound
    a, b = led.busy_intervals(0)
    for f in (f_a, f_b):
        # no saturated stretch may overlap the placed transfer
        assert not np.any((a < f + dur) & (b > f))


def _check_unlimited_identity(reqs, lo, dur):
    """capacity=None: whatever was reserved, earliest_fit is `lo`."""
    led = GSResourceLedger(_NUM_STATIONS, None)
    for t0, d, gi in reqs:
        led.reserve(gi, t0, t0 + d)
    for gi in range(_NUM_STATIONS):
        assert led.earliest_fit(gi, lo, _HI, dur) == lo
        assert led.free_runs(gi, lo, lo + dur)[0].size == 1


def _check_segmented_plan(payload, t_ready, plane, slot, bookings):
    """Segmented plans conserve bits, serialize legs, alternate
    stations, stay inside windows, and avoid saturated stretches."""
    cfg, walker, gss, pred = _world()
    led = GSResourceLedger(2, 1)
    for lo, dur in bookings:
        led.reserve(0, lo, lo + dur)        # pre-load station 0
    plan = plan_segmented_transfer(
        walker=walker, predictor=pred, sat=Satellite(plane, slot),
        t_ready=t_ready, link=LinkConfig(), payload_bits=payload,
        ledger=led,
    )
    if plan is None:                        # infeasible inside the horizon
        return
    assert abs(plan.total_bits - payload) < max(1e-6 * payload, 1e-3)
    assert plan.t_start >= t_ready
    for leg in plan.segments:
        assert leg.bits > 0
        assert leg.window_start <= leg.t_start < leg.t_end
        assert leg.t_end <= leg.window_end + 1e-9
        a, b = led.busy_intervals(leg.gs_index)
        assert not np.any((a < leg.t_end) & (b > leg.t_start))
    for prev, nxt in zip(plan.segments, plan.segments[1:]):
        assert prev.t_end <= nxt.t_start + 1e-9
        assert prev.gs_index != nxt.gs_index


def _ledger_state(led):
    """Comparable snapshot of a ledger's full occupancy state."""
    return [
        (
            sorted(zip(*map(tuple, led.reservations(gi)))),
            tuple(map(tuple, led.busy_intervals(gi))),
            tuple(map(tuple, led.free_runs(gi, 0.0, _HI))),
        )
        for gi in range(led.num_stations)
    ]


def _check_release_round_trip(cap, reqs, extra):
    """``reserve`` -> ``release`` round-trips the ledger to its prior
    occupancy (busy intervals, free runs and the reservation list are
    all restored), in any release order; releasing an interval that was
    never booked raises."""
    led = GSResourceLedger(_NUM_STATIONS, cap)
    for lo, d, gi in reqs:
        led.reserve(gi, lo, lo + d)
    before = _ledger_state(led)
    placed = []
    for lo, d, gi in extra:
        t0 = led.earliest_fit(gi, lo, _HI, d)
        led.reserve(gi, t0, t0 + d)
        placed.append((gi, t0, t0 + d))
    # interleaved order: releases need not mirror the booking order
    for gi, t0, t1 in placed[1::2] + placed[0::2]:
        led.release(gi, t0, t1)
    assert _ledger_state(led) == before
    with np.testing.assert_raises(ValueError):
        led.release(0, -2.0, -1.0)          # never booked


# --- hypothesis entry points --------------------------------------------------
@given(cap=_caps, reqs=_requests)
@settings(max_examples=25, deadline=None)
def test_occupancy_never_exceeds_capacity(cap, reqs):
    _check_capacity_respected(cap, reqs)


@given(cap=_caps, reqs=_requests, extra=_requests)
@settings(max_examples=25, deadline=None)
def test_reserve_release_round_trips(cap, reqs, extra):
    _check_release_round_trip(cap, reqs, extra)


@given(cap=_caps, reqs=_requests, lo1=_times, lo2=_times, dur=_durations)
@settings(max_examples=25, deadline=None)
def test_earliest_fit_monotone_and_feasible(cap, reqs, lo1, lo2, dur):
    _check_earliest_fit_monotone(cap, reqs, lo1, lo2, dur)


@given(reqs=_requests, lo=_times, dur=_durations)
@settings(max_examples=25, deadline=None)
def test_unlimited_capacity_is_identity(reqs, lo, dur):
    _check_unlimited_identity(reqs, lo, dur)


@given(
    payload=st.floats(min_value=1e6, max_value=8e8,
                      allow_nan=False, allow_infinity=False),
    t_ready=st.floats(min_value=0.0, max_value=12 * 3600.0,
                      allow_nan=False, allow_infinity=False),
    plane=st.integers(0, 1),
    slot=st.integers(0, 3),
    bookings=st.lists(st.tuples(_times, _durations), max_size=5),
)
@settings(max_examples=15, deadline=None)
def test_segmented_plans_conserve_bits(payload, t_ready, plane, slot,
                                       bookings):
    _check_segmented_plan(payload, t_ready, plane, slot, bookings)


# --- seeded sweep over the same properties (runs without hypothesis) ----------
def test_invariants_random_sweep():
    rng = np.random.default_rng(0)
    for _ in range(25):
        n = int(rng.integers(1, 30))
        reqs = [
            (float(rng.uniform(0, 1e5)), float(rng.uniform(1e-3, 1e4)),
             int(rng.integers(0, _NUM_STATIONS)))
            for _ in range(n)
        ]
        cap = int(rng.integers(1, 5))
        extra = [
            (float(rng.uniform(0, 1e5)), float(rng.uniform(1e-3, 1e4)),
             int(rng.integers(0, _NUM_STATIONS)))
            for _ in range(int(rng.integers(1, 8)))
        ]
        _check_capacity_respected(cap, reqs)
        _check_release_round_trip(cap, reqs, extra)
        _check_earliest_fit_monotone(
            cap, reqs, float(rng.uniform(0, 1e5)),
            float(rng.uniform(0, 1e5)), float(rng.uniform(1e-3, 1e4)),
        )
        _check_unlimited_identity(
            reqs, float(rng.uniform(0, 1e5)), float(rng.uniform(1e-3, 1e4)),
        )
    for _ in range(8):
        bookings = [
            (float(rng.uniform(0, 8e4)), float(rng.uniform(10.0, 5e3)))
            for _ in range(int(rng.integers(0, 5)))
        ]
        _check_segmented_plan(
            float(rng.uniform(1e6, 8e8)),
            float(rng.uniform(0, 12 * 3600.0)),
            int(rng.integers(0, 2)), int(rng.integers(0, 4)), bookings,
        )
