"""Sharding rules + FedLEO hierarchical training step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import build_model, get_smoke_config
from repro.launch.sharding import batch_sharding, spec_for_leaf
from repro.optim import get_optimizer
from repro.train.fedleo_step import (
    make_fedleo_aggregate,
    make_fedleo_local_step,
    replicate_for_orbits,
)
from repro.train.steps import TrainState, make_train_step


class _FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def test_spec_rules():
    mesh = _FakeMesh()
    # column-parallel attention projection, 96 heads divisible by 16
    s = spec_for_leaf("layers/block0/attn/wq", (12288, 96, 128), mesh)
    assert s == P("data", "model", None)
    # GQA kv with 8 heads: NOT divisible by model=16 -> replicated heads
    s = spec_for_leaf("layers/block0/attn/wk", (12288, 8, 128), mesh)
    assert s == P("data", None, None)
    # scanned stack gains a leading None
    s = spec_for_leaf("layers/block0/ffn/w_gate", (88, 12288, 28672), mesh)
    assert s == P(None, "data", "model")
    # MoE expert stack: experts over model (expert parallel)
    s = spec_for_leaf("layers/block0/moe/w_gate", (61, 384, 7168, 2048),
                      mesh)
    assert s == P(None, "model", "data", None)
    # shared expert inside moe params keeps the dense rule
    s = spec_for_leaf("layers/block0/moe/shared/w_gate", (7168, 4096), mesh)
    assert s == P("data", "model")
    # norms replicate
    s = spec_for_leaf("layers/block0/ln_attn/scale", (88, 12288), mesh)
    assert s == P(None, None)
    # embedding: vocab over model, d_model over data
    s = spec_for_leaf("embed/table", (32768, 12288), mesh)
    assert s == P("model", "data")
    # adafactor factored row (rank reduced): replicated
    s = spec_for_leaf("opt_state/factored/w_gate", (12288,), mesh)
    assert s == P(None)


def test_batch_sharding_policy():
    mesh = _FakeMesh()
    assert batch_sharding(mesh, 256) == ("pod", "data")
    assert batch_sharding(mesh, 32) == ("pod", "data")
    assert batch_sharding(mesh, 2) == ("pod",)
    assert batch_sharding(mesh, 1) == ()


def test_fedleo_local_step_independent_replicas():
    """Before aggregation, orbit replicas evolve independently (no
    cross-replica leakage); aggregation brings them back together."""
    cfg = get_smoke_config("gemma-7b")
    model = build_model(cfg)
    opt = get_optimizer("sgd", 1e-2)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    R = 2
    state_r = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (R,) + x.shape), state
    )
    rng = np.random.default_rng(0)
    # different data per replica
    batches = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (R, 1, 2, 32)), jnp.int32
        )
    }
    local_step = jax.jit(make_fedleo_local_step(model, opt))
    state2, metrics = local_step(state_r, batches)
    p0 = jax.tree_util.tree_leaves(state2.params)[3]
    # replicas saw different batches -> diverged
    assert not np.allclose(np.asarray(p0[0]), np.asarray(p0[1]))

    aggregate = jax.jit(make_fedleo_aggregate())
    state3 = aggregate(state2, jnp.asarray([0.5, 0.5]))
    for leaf in jax.tree_util.tree_leaves(state3.params):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   rtol=1e-6)


def test_fedleo_aggregate_weighted_mean():
    """Aggregation = eq. (4): weighted mean over orbit replicas."""
    a = {"w": jnp.asarray([[1.0, 1.0], [3.0, 3.0]])}   # two replicas
    state = TrainState(params=a, opt_state=(), step=jnp.zeros((2,)))
    agg = make_fedleo_aggregate()(state, jnp.asarray([0.75, 0.25]))
    np.testing.assert_allclose(agg.params["w"][0], [1.5, 1.5], rtol=1e-6)
    np.testing.assert_allclose(agg.params["w"][1], [1.5, 1.5], rtol=1e-6)


def test_replicate_for_orbits():
    tree = {"w": jnp.ones((3, 4))}
    out = replicate_for_orbits(tree, 5)
    assert out["w"].shape == (5, 3, 4)
