"""Optimizers, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.data import (
    label_histogram,
    make_classification_dataset,
    make_segmentation_dataset,
    make_token_dataset,
    partition_iid,
    partition_noniid_by_orbit,
)
from repro.data.partition import stack_client_arrays
from repro.optim import adafactor, adam, clip_by_global_norm, get_optimizer, \
    momentum, sgd
from repro.optim.optimizers import apply_updates


# --- optimizers ------------------------------------------------------------------
def _quadratic_converges(opt, steps=300):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        updates, state = opt.update(g, state, params)
        params = apply_updates(params, updates)
    return float(loss(params))


@pytest.mark.parametrize("name,lr,steps", [
    ("sgd", 0.1, 300), ("momentum", 0.05, 300), ("adam", 0.1, 300),
    ("adafactor", 0.2, 800),    # relative-update clipping -> slower tail
])
def test_optimizers_converge_quadratic(name, lr, steps):
    assert _quadratic_converges(get_optimizer(name, lr), steps) < 1e-2


def test_adafactor_state_is_factored():
    opt = adafactor(0.01)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros(7)}
    state = opt.init(params)
    row, col = state.factored["w"]
    assert row.shape == (64,) and col.shape == (32,)
    assert state.factored["b"].shape == (7,)   # 1-D: full second moment


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped = clip_by_global_norm(g, 1.0)
    norm = float(jnp.linalg.norm(clipped["a"]))
    assert abs(norm - 1.0) < 1e-5
    small = {"a": jnp.full((4,), 0.01)}
    np.testing.assert_allclose(clip_by_global_norm(small, 1.0)["a"],
                               small["a"], rtol=1e-6)


# --- data ------------------------------------------------------------------------
def test_train_test_same_distribution():
    train = make_classification_dataset("mnist-like", 256, seed=0)
    test = make_classification_dataset("mnist-like", 256, seed=99)
    # same class patterns: per-class means of train/test must correlate
    for c in range(3):
        mtr = train.x[train.y == c].mean(0).ravel()
        mte = test.x[test.y == c].mean(0).ravel()
        r = np.corrcoef(mtr, mte)[0, 1]
        assert r > 0.5, f"class {c} corr {r}"


def test_noniid_partition_matches_paper():
    """§V-A: 2 orbits -> 4 classes; 3 orbits -> remaining 6 classes."""
    ds = make_classification_dataset("mnist-like", 2000, seed=1)
    clients = partition_noniid_by_orbit(ds, 5, 8)
    assert len(clients) == 40
    for c in clients:
        classes = set(np.unique(c.data.y).tolist())
        if c.plane < 2:
            assert classes <= {0, 1, 2, 3}
        else:
            assert classes <= {4, 5, 6, 7, 8, 9}
    total = sum(cl.num_samples for cl in clients)
    assert total == 2000


def test_iid_partition_even():
    ds = make_classification_dataset("mnist-like", 400, seed=2)
    clients = partition_iid(ds, 5, 8)
    sizes = [c.num_samples for c in clients]
    assert max(sizes) - min(sizes) <= 1
    hist = label_histogram(clients[0].data)
    assert (hist > 0).sum() >= 5   # each client sees most classes


def test_stack_client_arrays_padding():
    ds = make_classification_dataset("mnist-like", 101, seed=3)
    clients = partition_iid(ds, 2, 2)
    xs, ys, counts = stack_client_arrays(clients)
    assert xs.shape[0] == 4
    assert xs.shape[1] == max(counts)
    assert counts.sum() == 101


def test_segmentation_dataset():
    ds = make_segmentation_dataset(num_samples=8, size=32, seed=0)
    assert ds.x.shape == (8, 32, 32, 3)
    assert ds.y.shape == (8, 32, 32)
    assert set(np.unique(ds.y)) <= {0, 1}
    frac = ds.y.mean()
    assert 0.01 < frac < 0.5   # roads present but sparse


def test_token_dataset_structure():
    ds = make_token_dataset(num_sequences=8, seq_len=64, vocab_size=128,
                            seed=0)
    assert ds.x.shape == (8, 64)
    assert ds.x.max() < 128
    # Markov structure: repeat-token rate above uniform chance
    repeats = (ds.x[:, 1:] == ds.x[:, :-1]).mean()
    assert repeats > 2.0 / 128


# --- checkpointing --------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "opt": [jnp.ones(4, jnp.float32), jnp.zeros((), jnp.int32)],
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 10, tree)
    save_checkpoint(d, 20, tree)
    assert latest_step(d) == 20
    restored = restore_checkpoint(d, 10, tree)
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])
    np.testing.assert_array_equal(restored["opt"][0], tree["opt"][0])
