"""ScheduleSanitizer: real schedules pass, corrupted schedules fail.

Two halves, mirroring the tool's contract:

  * **Soundness on real schedules**: every strategy's planning surface
    (FedLEO plane rounds, FedLEOGrid cluster rounds, the naive-sink /
    async booking path and its release->readmit cycle) across 1-3
    ground stations, ring and grid topologies, contention-free and
    RB-contended arms, produces ZERO violations — the paper's
    eqs. 13-16 / 15 / 21-22 hold on everything the planners emit.
  * **Completeness on corrupted schedules**: hand-corrupted decisions
    (oversubscribed RBs, a leg outside every visibility window,
    non-conserved segment payload, overlapping / non-switching legs,
    a regressive re-admission, a leaked reservation) are each rejected
    with the right rule tag.

The deterministic parametrized sweep runs everywhere; the hypothesis
property test widens the same invariant over random (topology,
capacity, train-time, probe-time) draws and auto-skips when hypothesis
is not installed (tests/conftest.py shim) — CI's `property` job runs
it for real.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.sanitizer import (
    ScheduleSanitizer,
    ScheduleViolation,
    Violation,
)
from repro.comms import CommsEnvironment, GSResourceLedger, LinkConfig
from repro.comms.environment import PendingUpload, TransferDecision
from repro.comms.isl import ISLConfig, isl_hop_time
from repro.comms.routing import ISLPlan, get_routing_table
from repro.core.fedleo import (
    make_clusters,
    plan_cluster_round,
    plan_plane_round,
)
from repro.core.propagation import broadcast_schedule, ring_hops_matrix
from repro.core.scheduling import TransferSegment
from repro.orbits.constellation import (
    ConstellationConfig,
    GroundStation,
    Satellite,
    WalkerDelta,
)
from repro.orbits.prediction import VisibilityPredictor
from repro.orbits.topology import TopologyConfig

PAYLOAD = 3.2e7
HORIZON_S = 24 * 3600.0
CFG = ConstellationConfig(num_planes=3, sats_per_plane=6)


@pytest.fixture(scope="module")
def world():
    walker = WalkerDelta(CFG)
    a = GroundStation()
    b = GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                      name="GS-B")
    c = GroundStation(lat_deg=a.lat_deg - 6.0, lon_deg=a.lon_deg + 9.0,
                      name="GS-C")
    segments = {1: [a], 2: [a, b], 3: [a, b, c]}
    preds = {
        n: VisibilityPredictor(walker, gss, horizon_s=HORIZON_S)
        for n, gss in segments.items()
    }
    return walker, segments, preds


def _env(world, n_gs, capacity=None, handover=False, strict=True):
    """A sanitized session over the shared predictor."""
    walker, segments, preds = world
    ledger = (
        GSResourceLedger(n_gs, capacity) if capacity is not None else None
    )
    env = CommsEnvironment(
        walker=walker, predictor=preds[n_gs], link=LinkConfig(),
        isl=ISLConfig(), ledger=ledger, handover=handover,
        gs=segments[n_gs],
    )
    ScheduleSanitizer.attach(env, strict=strict)
    return env


def _price_ring(env, train_time_s=600.0, t=0.0):
    """Commit one FedLEO ring round through the sanitized session."""
    K = env.walker.config.sats_per_plane
    train = np.full(K, train_time_s)
    done = []
    for plane in range(env.walker.config.num_planes):
        plan = plan_plane_round(
            env=env, isl=env.isl, plane=plane, t=t,
            payload_bits=PAYLOAD, train_times=train,
        )
        if plan is None:
            return None
        env.commit(plan.decision)
        done.append(plan.decision.t_upload_done)
    return max(done)


def _price_grid(env, routing, cluster_planes=2, train_time_s=600.0, t=0.0):
    """Commit one FedLEOGrid cluster round through the session."""
    K = env.walker.config.sats_per_plane
    done = []
    for planes in make_clusters(env.walker.config.num_planes,
                                cluster_planes):
        train = np.full(len(planes) * K, train_time_s)
        plan = plan_cluster_round(
            env=env, routing=routing, planes=planes, t=t,
            payload_bits=PAYLOAD, train_times=train,
        )
        if plan is None:
            return None
        env.commit(plan.decision)
        done.append(plan.decision.t_upload_done)
    return max(done)


def _price_async(env, train_time_s=600.0, t=0.0, readmit=True):
    """Naive-sink async booking: download -> flood -> train -> upload,
    then a release event and (optionally) re-admission."""
    K = env.walker.config.sats_per_plane
    t_hop = isl_hop_time(env.isl, PAYLOAD)
    hops = ring_hops_matrix(K)
    pending = []
    for plane in range(env.walker.config.num_planes):
        dl = env.first_visible_download(plane, t, PAYLOAD)
        if dl is None:
            return None
        src_slot, t_recv = dl
        events = broadcast_schedule(K, [src_slot], [t_recv], PAYLOAD,
                                    env.isl)
        t_done = np.array(
            [events[s].t_receive + train_time_s for s in range(K)]
        )
        sink = env.naive_sink_slot(plane, float(t_done.max()))
        if sink is None:
            return None
        t_ready = float(np.max(t_done + hops[sink] * t_hop))
        dec = env.plan_upload(Satellite(plane, sink), t_ready, PAYLOAD)
        if dec is None:
            return None
        res = env.commit(dec)
        pending.append(PendingUpload(
            plane, Satellite(plane, sink), t_ready, PAYLOAD, dec, res
        ))
    victim = min(range(len(pending)),
                 key=lambda i: (pending[i].decision.t_start, i))
    env.release(pending[victim].reservation)
    survivors = [p for i, p in enumerate(pending) if i != victim]
    if readmit and survivors:
        survivors, _ = env.readmit(survivors, t)
    return max(p.decision.t_done for p in survivors) if survivors else None


def _grid_routing():
    return get_routing_table(
        CFG, TopologyConfig(kind="grid"),
        ISLPlan(intra=ISLConfig(), inter=ISLConfig()), PAYLOAD,
    )


# --- soundness: real schedules are sanitizer-clean ----------------------------
@pytest.mark.parametrize("n_gs", [1, 2, 3])
@pytest.mark.parametrize("capacity", [None, 8, 1])
def test_ring_rounds_clean(world, n_gs, capacity):
    env = _env(world, n_gs, capacity=capacity)
    t_round = _price_ring(env)
    assert t_round is not None
    assert env.sanitizer.report() == []
    assert env.finish_session(t_round) == []


@pytest.mark.parametrize("n_gs", [1, 2, 3])
@pytest.mark.parametrize("capacity", [None, 1])
def test_grid_rounds_clean(world, n_gs, capacity):
    env = _env(world, n_gs, capacity=capacity)
    t_round = _price_grid(env, _grid_routing())
    assert t_round is not None
    assert env.sanitizer.report() == []
    assert env.finish_session(t_round) == []


@pytest.mark.parametrize("n_gs", [2, 3])
def test_handover_rounds_clean(world, n_gs):
    """Segmented (station-handover) uploads pass the segment rules."""
    env = _env(world, n_gs, capacity=1, handover=True)
    t_round = _price_ring(env, train_time_s=60.0)
    assert t_round is not None
    assert env.sanitizer.report() == []
    assert env.finish_session(t_round) == []


@pytest.mark.parametrize("n_gs", [1, 2])
@pytest.mark.parametrize("readmit", [False, True])
def test_async_booking_and_readmit_clean(world, n_gs, readmit):
    """The async book/release/readmit cycle — including the eqs. 21-22
    monotonicity check ``readmit`` runs under — is violation-free, and
    the strategy-declared open queue is not a leak."""
    env = _env(world, n_gs, capacity=1)
    t_round = _price_async(env, readmit=readmit)
    assert t_round is not None
    assert env.sanitizer.report() == []
    assert env.finish_session(t_round) == []


def test_sanitized_run_is_bit_identical(world):
    """Observing must never perturb: the same round priced with and
    without the sanitizer produces the same completion times."""
    plain = _env(world, 2, capacity=1)
    plain.sanitizer = None
    sanitized = _env(world, 2, capacity=1)
    assert _price_ring(plain) == _price_ring(sanitized)


# --- completeness: corrupted schedules are rejected ---------------------------
def _upload(env, plane=0, slot=0, t=0.0):
    dec = env.plan_upload(Satellite(plane, slot), t, PAYLOAD)
    assert dec is not None
    return dec


def test_rejects_oversubscribed_station(world):
    """Two identical bookings on a 1-RB station: the second commit
    must fail eqs. 13-16 BEFORE touching the ledger."""
    env = _env(world, 1, capacity=1)
    dec = _upload(env)
    env.commit(dec)
    n_before = env.ledger.num_reserved()
    with pytest.raises(ScheduleViolation, match="rb-capacity"):
        env.commit(dec)
    # strict rejection left the ledger exactly as it was
    assert env.ledger.num_reserved() == n_before


def test_oversubscription_within_capacity_is_clean(world):
    """The same double booking is legal at capacity 2."""
    env = _env(world, 1, capacity=2)
    dec = _upload(env)
    env.commit(dec)
    env.commit(dec)
    assert env.sanitizer.report() == []


def test_rejects_leg_outside_visibility_window(world):
    env = _env(world, 1, capacity=1)
    dec = _upload(env)
    w = dec.window
    bad = dataclasses.replace(
        dec, t_start=w.t_end + 100.0, t_done=w.t_end + 200.0
    )
    with pytest.raises(ScheduleViolation, match="window-containment"):
        env.commit(bad)


def test_rejects_nonconserved_segment_payload(world):
    env = _env(world, 2, capacity=1)
    dec = _upload(env)
    w = dec.window
    mid = (dec.t_start + dec.t_done) / 2.0
    legs = (
        TransferSegment(w.gs_index, dec.t_start, mid, 1.0,
                        w.t_start, w.t_end),
    )
    bad = dataclasses.replace(dec, segments=legs)
    with pytest.raises(ScheduleViolation, match="payload-conservation"):
        env.commit(bad)


def test_rejects_overlapping_segments(world):
    env = _env(world, 2, capacity=None)
    dec = _upload(env)
    w = dec.window
    t0, t1 = dec.t_start, dec.t_done
    mid = (t0 + t1) / 2.0
    legs = (
        TransferSegment(w.gs_index, t0, mid + 1.0, PAYLOAD / 2,
                        w.t_start, w.t_end),
        # overlaps the first leg's tail (and on another station, so the
        # station-switch rule stays satisfied: this isolates overlap)
        TransferSegment((w.gs_index + 1) % 2, mid, t1, PAYLOAD / 2,
                        w.t_start, w.t_end),
    )
    bad = dataclasses.replace(dec, segments=legs)
    with pytest.raises(ScheduleViolation, match="segment-order"):
        env.commit(bad)


def test_rejects_non_switching_segments(world):
    env = _env(world, 2, capacity=None)
    dec = _upload(env)
    w = dec.window
    t0, t1 = dec.t_start, dec.t_done
    mid = (t0 + t1) / 2.0
    legs = (
        TransferSegment(w.gs_index, t0, mid, PAYLOAD / 2,
                        w.t_start, w.t_end),
        TransferSegment(w.gs_index, mid, t1, PAYLOAD / 2,
                        w.t_start, w.t_end),
    )
    bad = dataclasses.replace(dec, segments=legs)
    with pytest.raises(ScheduleViolation,
                       match="must switch stations"):
        env.commit(bad)


def test_rejects_readmit_regression(world):
    env = _env(world, 1)
    with pytest.raises(ScheduleViolation, match="readmit-regression"):
        env.sanitizer.observe_readmit(
            before=[("up-0", 100.0)], after=[("up-0", 250.0)],
        )


def test_reports_reservation_leak(world):
    """A booking entirely beyond sim end, never released and not in
    the strategy's open queue, is a leak — unless declared open, or
    the leak check is waived for an aborted run."""
    env = _env(world, 1, capacity=1, strict=False)
    dec = _upload(env, t=3600.0)
    res = env.commit(dec)
    leaks = env.finish_session(dec.t_start - 10.0)
    assert [v.rule for v in leaks] == ["reservation-leak"]
    # the same booking declared as the async strategy's live queue
    env2 = _env(world, 1, capacity=1, strict=False)
    res2 = env2.commit(_upload(env2, t=3600.0))
    assert env2.finish_session(
        dec.t_start - 10.0, open_rids=frozenset({res2.rid})
    ) == []
    # ... or released in time
    env3 = _env(world, 1, capacity=1, strict=False)
    dec3 = _upload(env3, t=3600.0)
    env3.release(env3.commit(dec3))
    assert env3.finish_session(dec3.t_start - 10.0) == []


def test_nonstrict_collects_instead_of_raising(world):
    env = _env(world, 1, capacity=1, strict=False)
    dec = _upload(env)
    env.commit(dec)
    env.commit(dec)                     # oversubscribes, but collects
    report = env.sanitizer.report()
    assert [v.rule for v in report] == ["rb-capacity"]
    assert all(isinstance(v, Violation) for v in report)
    assert "station 0" in str(report[0])


def test_simconfig_wires_sanitizer():
    """SimConfig.sanitize (the tier-1 default) attaches the sanitizer
    through ``CommsEnvironment.from_sim``; sanitize=False does not."""
    from repro.core.engine import SimConfig

    sim = SimConfig(constellation=CFG, horizon_hours=6.0)
    assert sim.sanitize
    env = CommsEnvironment.from_sim(sim)
    assert env.sanitizer is not None and env.sanitizer.strict
    env_off = CommsEnvironment.from_sim(
        dataclasses.replace(sim, sanitize=False)
    )
    assert env_off.sanitizer is None


# --- property test: the invariant over random draws ---------------------------
@given(
    n_gs=st.integers(min_value=1, max_value=3),
    capacity=st.sampled_from([None, 1, 2, 8]),
    kind=st.sampled_from(["ring", "grid", "async"]),
    train_time_s=st.floats(min_value=30.0, max_value=3600.0),
    t0_hours=st.floats(min_value=0.0, max_value=6.0),
)
@settings(max_examples=20, deadline=None)
def test_property_schedules_are_sanitizer_clean(
    n_gs, capacity, kind, train_time_s, t0_hours
):
    """Any (ground segment, contention, strategy-surface, round-start)
    draw yields a violation-free schedule."""
    walker = WalkerDelta(CFG)
    a = GroundStation()
    gss = [
        a,
        GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                      name="GS-B"),
        GroundStation(lat_deg=a.lat_deg - 6.0, lon_deg=a.lon_deg + 9.0,
                      name="GS-C"),
    ][:n_gs]
    pred = VisibilityPredictor(walker, gss, horizon_s=HORIZON_S)
    ledger = (
        GSResourceLedger(n_gs, capacity) if capacity is not None else None
    )
    env = CommsEnvironment(
        walker=walker, predictor=pred, link=LinkConfig(), isl=ISLConfig(),
        ledger=ledger, gs=gss,
    )
    ScheduleSanitizer.attach(env)
    t0 = t0_hours * 3600.0
    if kind == "ring":
        t_round = _price_ring(env, train_time_s=train_time_s, t=t0)
    elif kind == "grid":
        t_round = _price_grid(env, _grid_routing(),
                              train_time_s=train_time_s, t=t0)
    else:
        t_round = _price_async(env, train_time_s=train_time_s, t=t0)
    assert env.sanitizer.report() == []
    if t_round is not None:
        assert env.finish_session(t_round) == []
