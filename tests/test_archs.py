"""Per-architecture smoke tests: reduced same-family variants run one
forward/train step + one decode step on CPU, asserting shapes + no NaNs.
(The FULL configs are exercised via the dry-run only.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, build_model, get_config, get_smoke_config
from repro.optim import get_optimizer
from repro.train.steps import (
    TrainState,
    make_serve_step,
    make_train_step,
)

B, S = 2, 64


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
        )
    }
    if cfg.family == "vlm":
        batch["extra"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision.num_patches, cfg.d_model)),
            jnp.bfloat16,
        )
    if cfg.family == "audio":
        batch["source"] = jnp.asarray(
            rng.standard_normal((B, 32, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_limits(arch):
    """Smoke configs respect the reduced-variant contract."""
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    full = get_config(arch)
    assert cfg.family == full.family
    assert cfg.activation == full.activation


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = model.init(jax.random.PRNGKey(0))
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(model, opt))
    batch = _batch(cfg, rng)
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    # a fresh model's LM loss must be near ln(vocab)
    assert abs(loss - np.log(cfg.vocab_size)) < 1.5
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))),
        jax.tree_util.tree_map(jnp.subtract, state2.params, state.params),
        0.0,
    )
    assert delta > 0.0
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.family == "audio":
        src = jnp.asarray(rng.standard_normal((B, 32, cfg.d_model)),
                          jnp.bfloat16)
        cache = model.init_cache(params, src, max_len=32)
    else:
        cache = model.init_cache(B, 32)
    serve = jax.jit(make_serve_step(model))
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((), jnp.int32)
    for _ in range(3):
        logits, cache = serve(params, tok, cache, pos)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
        pos = pos + 1


@pytest.mark.parametrize("arch", ["gemma-7b", "mamba2-780m", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full-sequence forward
    logits (cache correctness)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    s = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, s)), jnp.int32)
    full_logits, _ = model.forward(params, tokens)
    cache = model.init_cache(1, s)
    outs = []
    for t in range(s):
        logits, cache = model.decode_step(
            params, tokens[:, t: t + 1], cache, jnp.asarray(t, jnp.int32)
        )
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned dimensions."""
    spec = {
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
    }
    for arch, (nl, dm, nh, kv, dff, vocab) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == nl, arch
        assert cfg.d_model == dm, arch
        assert cfg.num_heads == nh, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == dff, arch
        assert cfg.vocab_size == vocab, arch
    # MoE / SSM extras
    assert get_config("llama4-maverick-400b-a17b").moe.num_experts == 128
    assert get_config("llama4-maverick-400b-a17b").moe.top_k == 1
    assert get_config("kimi-k2-1t-a32b").moe.num_experts == 384
    assert get_config("kimi-k2-1t-a32b").moe.top_k == 8
    assert get_config("mamba2-780m").ssm.state_dim == 128
    assert get_config("zamba2-1.2b").ssm.state_dim == 64
    assert get_config("gemma-7b").resolved_head_dim == 256
