"""CommsEnvironment session API: golden equivalence + lifecycle.

The session object is a pure re-homing of the scheduling machinery —
every legacy free function is now a thin shim over it — so the
load-bearing guarantee is *bit-identical equivalence*: for any
(ground segment, topology, contention, handover) configuration, the
shim and the session method must return exactly the same decisions,
and planning through the session must book exactly the same ledger
state the legacy ``reserve_decision`` path did.

Also covered: the reservation lifecycle (``commit`` -> ``release``
round-trips the ledger; partial release truncates; ``on_release``
callbacks fire with the freed legs) and the event-driven async
re-admission built on it (``readmit`` never makes any queued upload
complete later, and moves uploads up into capacity freed by a
release).
"""
import dataclasses

import numpy as np
import pytest

from repro.comms import CommsEnvironment, GSResourceLedger, LinkConfig
from repro.comms.environment import PendingUpload, TransferDecision
from repro.comms.isl import ISLConfig, isl_hop_time
from repro.comms.link import downlink_time, uplink_time
from repro.comms.routing import ISLPlan, RoutingTable
from repro.core.propagation import ring_hops_matrix
from repro.core.scheduling import (
    HandoverSpec,
    earliest_transfer,
    naive_sink_slot,
    reserve_decision,
    select_sink,
    select_sink_cluster,
    symmetric_transfer,
)
from repro.orbits.constellation import (
    ConstellationConfig,
    GroundStation,
    Satellite,
    WalkerDelta,
)
from repro.orbits.prediction import VisibilityPredictor
from repro.orbits.topology import TopologyConfig, get_isl_topology

PAYLOAD = 3.2e7
HORIZON_S = 24 * 3600.0


@pytest.fixture(scope="module")
def world():
    """One small constellation, ground segments of 1-3 stations, and a
    grid routing table — every golden case draws from here."""
    cfg = ConstellationConfig(num_planes=2, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    a = GroundStation()
    b = GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                      name="GS-B")
    c = GroundStation(lat_deg=a.lat_deg - 6.0, lon_deg=a.lon_deg + 9.0,
                      name="GS-C")
    segments = {1: [a], 2: [a, b], 3: [a, b, c]}
    preds = {
        n: VisibilityPredictor(walker, gss, horizon_s=HORIZON_S)
        for n, gss in segments.items()
    }
    topo = get_isl_topology(cfg, TopologyConfig(kind="grid"))
    isl = ISLConfig()
    routing = RoutingTable(topo, ISLPlan(intra=isl, inter=isl), PAYLOAD)
    return cfg, walker, segments, preds, isl, routing


def _env(world, n_gs, capacity=None, handover=False):
    cfg, walker, segments, preds, isl, _ = world
    ledger = (
        GSResourceLedger(n_gs, capacity) if capacity is not None else None
    )
    return CommsEnvironment(
        walker=walker, predictor=preds[n_gs], link=LinkConfig(), isl=isl,
        ledger=ledger, handover=handover, gs=segments[n_gs],
    )


def _mirror_ledgers(n_gs, capacity):
    """Two independent but identical ledgers, one per API surface."""
    if capacity is None:
        return None, None
    return (GSResourceLedger(n_gs, capacity),
            GSResourceLedger(n_gs, capacity))


# --- golden equivalence: every legacy shim == the session method -------------
@pytest.mark.parametrize("n_gs", [1, 2, 3])
@pytest.mark.parametrize("handover", [False, True])
def test_earliest_transfer_matches_env(world, n_gs, handover):
    cfg, walker, segments, preds, isl, _ = world
    link = LinkConfig()
    led_a, led_b = _mirror_ledgers(n_gs, 1)
    env = CommsEnvironment(
        walker=walker, predictor=preds[n_gs], link=link, isl=isl,
        ledger=led_b, handover=handover, gs=segments[n_gs],
    )
    spec = HandoverSpec(link, PAYLOAD) if handover else None
    tt = symmetric_transfer(downlink_time, link, PAYLOAD)
    for plane in range(cfg.num_planes):
        for slot in range(0, cfg.sats_per_plane, 2):
            for t in (0.0, 3 * 3600.0, 11 * 3600.0):
                sat = Satellite(plane, slot)
                legacy = earliest_transfer(
                    walker=walker, predictor=preds[n_gs], sat=sat, t=t,
                    transfer_time=tt, ledger=led_a, handover=spec,
                )
                dec = env.plan_upload(sat, t, PAYLOAD)
                if legacy is None:
                    assert dec is None
                    continue
                assert isinstance(dec, TransferDecision)
                assert (dec.t_start, dec.t_done) == (legacy[0], legacy[1])
                assert dec.window == legacy[2]
                assert dec.segments == (tuple(legacy[3]) if handover else ())
                # both surfaces book; mirrored ledgers must stay equal
                env.commit(dec)
                if led_a is not None:
                    from repro.core.scheduling import reserve_transfer

                    reserve_transfer(led_a, legacy[2].gs_index, legacy[0],
                                     legacy[1],
                                     legacy[3] if handover else ())
                    for gi in range(n_gs):
                        np.testing.assert_array_equal(
                            led_a.reservations(gi)[0],
                            led_b.reservations(gi)[0],
                        )
                        np.testing.assert_array_equal(
                            led_a.reservations(gi)[1],
                            led_b.reservations(gi)[1],
                        )


@pytest.mark.parametrize("n_gs", [1, 2, 3])
@pytest.mark.parametrize("handover", [False, True])
@pytest.mark.parametrize("capacity", [None, 1])
def test_select_sink_matches_env(world, n_gs, handover, capacity):
    cfg, walker, segments, preds, isl, _ = world
    link = LinkConfig()
    led_a, led_b = _mirror_ledgers(n_gs, capacity)
    env = CommsEnvironment(
        walker=walker, predictor=preds[n_gs], link=link, isl=isl,
        ledger=led_b, handover=handover, gs=segments[n_gs],
    )
    rng = np.random.default_rng(7)
    for plane in range(cfg.num_planes):
        for base in (1800.0, 4 * 3600.0):
            done = base + rng.uniform(0, 900.0, cfg.sats_per_plane)
            a = select_sink(
                walker=walker, gs=segments[n_gs], predictor=preds[n_gs],
                link=link, isl=isl, plane=plane, t_train_done=done,
                payload_bits=PAYLOAD, ledger=led_a, handover=handover,
            )
            b = env.select_sink(
                plane=plane, t_train_done=done, payload_bits=PAYLOAD,
            )
            assert a == b
            if a is not None:
                reserve_decision(led_a, a)
                env.commit(b)


@pytest.mark.parametrize("n_gs", [1, 2, 3])
@pytest.mark.parametrize("handover", [False, True])
def test_select_sink_cluster_matches_env(world, n_gs, handover):
    """The grid path: one cluster spanning both planes, relay latency
    from the grid routing table."""
    cfg, walker, segments, preds, isl, routing = world
    link = LinkConfig()
    sats = [(p, s) for p in range(2) for s in range(cfg.sats_per_plane)]
    _, relay = routing.submatrix(routing.nodes_of(sats))
    led_a, led_b = _mirror_ledgers(n_gs, 1)
    env = CommsEnvironment(
        walker=walker, predictor=preds[n_gs], link=link, isl=isl,
        ledger=led_b, handover=handover, gs=segments[n_gs],
    )
    rng = np.random.default_rng(11)
    for base in (3600.0, 6 * 3600.0):
        done = base + rng.uniform(0, 1200.0, len(sats))
        a = select_sink_cluster(
            walker=walker, gs=segments[n_gs], predictor=preds[n_gs],
            link=link, sats=sats, relay_latency=relay, t_train_done=done,
            payload_bits=PAYLOAD, ledger=led_a, handover=handover,
        )
        b = env.select_sink_cluster(
            sats=sats, relay_latency=relay, t_train_done=done,
            payload_bits=PAYLOAD,
        )
        assert a == b
        if a is not None:
            reserve_decision(led_a, a)
            env.commit(b)


@pytest.mark.parametrize("n_gs", [1, 2, 3])
def test_naive_sink_slot_and_download_match_env(world, n_gs):
    cfg, walker, segments, preds, isl, _ = world
    env = _env(world, n_gs)
    for plane in range(cfg.num_planes):
        for t in (0.0, 2 * 3600.0, 9 * 3600.0):
            assert (naive_sink_slot(preds[n_gs], plane, t)
                    == env.naive_sink_slot(plane, t))
            from repro.core.scheduling import first_visible_download

            assert first_visible_download(
                walker=walker, gs=segments[n_gs], predictor=preds[n_gs],
                link=LinkConfig(), plane=plane, t=t, payload_bits=PAYLOAD,
            ) == env.first_visible_download(plane, t, PAYLOAD)


def test_plan_download_matches_uplink_shim(world):
    cfg, walker, segments, preds, isl, _ = world
    link = LinkConfig()
    env = _env(world, 2)
    tt = symmetric_transfer(uplink_time, link, PAYLOAD)
    for slot in range(cfg.sats_per_plane):
        sat = Satellite(0, slot)
        legacy = earliest_transfer(
            walker=walker, predictor=preds[2], sat=sat, t=0.0,
            transfer_time=tt,
        )
        dec = env.plan_download(sat, 0.0, PAYLOAD)
        assert (legacy is None) == (dec is None)
        if dec is not None:
            assert (dec.t_start, dec.t_done, dec.window) == legacy
            assert dec.legs == ()       # broadcasts book nothing


def test_gs_mismatch_check_lives_in_constructor(world):
    cfg, walker, segments, preds, isl, _ = world
    with pytest.raises(AssertionError):
        CommsEnvironment(
            walker=walker, predictor=preds[2], link=LinkConfig(),
            gs=segments[1],     # predictor built over two stations
        )
    with pytest.raises(ValueError):
        CommsEnvironment(
            walker=walker, predictor=preds[2], link=LinkConfig(),
            ledger=GSResourceLedger(3, 1),      # wrong station count
        )


# --- reservation lifecycle ----------------------------------------------------
def test_commit_release_round_trips_ledger(world):
    env = _env(world, 2, capacity=1)
    w = env.predictor.windows_of(Satellite(0, 0))[0]
    dec = TransferDecision("up", w.t_start, w.t_start + 60.0, w)
    before = [tuple(map(tuple, env.ledger.reservations(g))) for g in (0, 1)]
    res = env.commit(dec)
    legs = res.legs
    assert legs == ((w.gs_index, w.t_start, w.t_start + 60.0),)
    assert env.ledger.occupancy(w.gs_index, w.t_start + 1.0) == 1
    freed = env.release(res)
    assert freed == legs
    after = [tuple(map(tuple, env.ledger.reservations(g))) for g in (0, 1)]
    assert after == before
    assert env.release(res) == ()       # double release is a no-op


def test_partial_release_truncates(world):
    env = _env(world, 2, capacity=1)
    res = env.commit(TransferDecision(
        "up", 100.0, 200.0,
        env.predictor.windows_of(Satellite(0, 0))[0],
    ))
    (gi, t0, t1), = res.legs
    freed = env.release(res, at=150.0)
    assert freed == ((gi, 150.0, 200.0),)
    assert env.ledger.occupancy(gi, 120.0) == 1     # spent head kept
    assert env.ledger.occupancy(gi, 160.0) == 0     # tail freed


def test_on_release_fires_with_freed_legs(world):
    env = _env(world, 2, capacity=1)
    seen = []
    unsubscribe = env.on_release(lambda res, freed: seen.append(freed))
    res = env.commit(TransferDecision(
        "up", 10.0, 20.0, env.predictor.windows_of(Satellite(0, 0))[0],
    ))
    expected = res.legs
    env.release(res)
    assert seen == [expected]
    unsubscribe()
    res2 = env.commit(TransferDecision(
        "up", 30.0, 40.0, env.predictor.windows_of(Satellite(0, 0))[0],
    ))
    env.release(res2)
    assert len(seen) == 1               # unsubscribed: no second event


# --- event-driven async re-admission ------------------------------------------
def _pending_for(env, sat, t_ready):
    dec = env.plan_upload(sat, t_ready, PAYLOAD)
    assert dec is not None
    return PendingUpload(
        (sat.plane, sat.slot), sat, t_ready, PAYLOAD, dec,
        env.commit(dec),
    )


def test_readmit_moves_queued_upload_into_released_capacity(world):
    cfg, walker, segments, preds, isl, _ = world
    env = _env(world, 1, capacity=1)
    sat = Satellite(0, 0)
    first = _pending_for(env, sat, 0.0)
    # the same sink queues a second upload: on 1 RB it lands strictly
    # behind the first booking
    second = dataclasses.replace(_pending_for(env, sat, 0.0), key="second")
    contended = second.decision.t_done
    uncontended = env.derive(ledger=GSResourceLedger(1, 1)).plan_upload(
        sat, 0.0, PAYLOAD
    )
    assert contended > uncontended.t_done + 1e-9
    # the release event: the first upload aborts
    env.release(first.reservation)
    updated, repriced = env.readmit([second], t_now=0.0)
    assert repriced == 1
    assert updated[0].decision.t_done < contended - 1e-9
    assert abs(updated[0].decision.t_done - uncontended.t_done) <= 1e-9


def test_readmit_never_worsens_any_completion(world):
    cfg, walker, segments, preds, isl, _ = world
    env = _env(world, 2, capacity=1)
    pending = []
    rng = np.random.default_rng(3)
    for plane in range(2):
        for slot in range(4):
            t_ready = float(rng.uniform(0, 2 * 3600.0))
            dec = env.plan_upload(Satellite(plane, slot), t_ready, PAYLOAD)
            if dec is None:
                continue
            pending.append(PendingUpload(
                (plane, slot), Satellite(plane, slot), t_ready, PAYLOAD,
                dec, env.commit(dec),
            ))
    # release one mid-queue reservation, then re-admit
    env.release(pending[len(pending) // 2].reservation)
    survivors = (pending[:len(pending) // 2]
                 + pending[len(pending) // 2 + 1:])
    before = {p.key: p.decision.t_done for p in survivors}
    updated, _ = env.readmit(survivors, t_now=0.0)
    for p in updated:
        assert p.decision.t_done <= before[p.key] + 1e-9
    assert [p.key for p in updated] == [p.key for p in survivors]


def test_readmit_never_replans_into_the_past(world):
    """A queued upload re-prices from max(t_ready, now): once the clock
    has passed a released booking (and release_before purged history),
    re-admission must not adopt a plan that transmits in the past."""
    env = _env(world, 1, capacity=1)
    sat = Satellite(0, 0)
    first = _pending_for(env, sat, 0.0)
    second = dataclasses.replace(_pending_for(env, sat, 0.0), key="2")
    assert second.decision.t_start >= first.decision.t_done - 1e-9
    t_now = second.decision.t_start - 1e-3  # clock between the bookings
    env.release(first.reservation)          # the abort event
    env.release_before(t_now)               # engine housekeeping
    updated, _ = env.readmit([second], t_now=t_now)
    assert updated[0].decision.t_start >= t_now - 1e-9


def test_readmit_without_ledger_is_noop(world):
    env = _env(world, 1)
    dec = env.plan_upload(Satellite(0, 0), 0.0, PAYLOAD)
    p = PendingUpload((0, 0), Satellite(0, 0), 0.0, PAYLOAD, dec,
                      env.commit(dec))
    updated, repriced = env.readmit([p], t_now=0.0)
    assert repriced == 0 and updated == [p]


# --- engine wiring -------------------------------------------------------------
def test_from_sim_builds_the_strategy_session():
    from repro.core.engine import SimConfig

    sim = SimConfig(
        constellation=ConstellationConfig(num_planes=2, sats_per_plane=4),
        gs_rb_capacity=2, gs_handover=True, horizon_hours=6.0,
    )
    env = CommsEnvironment.from_sim(sim)
    assert env.handover is True
    assert env.ledger is not None and env.ledger.capacity == (2.0,)
    assert env.ground_stations == (sim.ground_station,)
    assert env.link is sim.link and env.isl is sim.isl


def test_async_strategy_reacts_to_release_event():
    """The in-engine wiring of SimConfig.async_readmit: a release event
    (an aborted pending upload) sets the strategy's hook flag, and the
    next step consumes it — re-admitting the queue with no pending
    completion ever getting later."""
    from repro.core import FederatedTask, SimConfig, TrainHyperparams
    from repro.core.baselines import FedAsync
    from repro.data import (
        make_classification_dataset,
        partition_noniid_by_orbit,
    )
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    ds = make_classification_dataset("mnist-like", num_samples=200, seed=0)
    test = make_classification_dataset("mnist-like", num_samples=100,
                                       seed=99)
    task = FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(4,),
                                   hidden=16),
        apply_fn=apply_cnn,
        clients=partition_noniid_by_orbit(ds, 5, 8),
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=TrainHyperparams(local_epochs=10, learning_rate=0.05,
                            batch_size=16),
        sim_epochs=1,
    )
    sim = SimConfig(horizon_hours=24.0, gs_rb_capacity=1,
                    async_readmit=True)
    strat = FedAsync(task, sim)
    assert strat.readmit and strat._pending
    assert not strat._capacity_freed    # no event yet: baseline stream
    # the event: the earliest-starting pending upload aborts
    key = min(strat._pending,
              key=lambda k: strat._pending[k].decision.t_start)
    strat.env.release(strat._pending.pop(key).reservation)
    assert strat._capacity_freed        # hook fired
    before = {k: p.decision.t_done for k, p in strat._pending.items()}
    strat._readmit_queued(0.0)          # what the next step runs first
    assert not strat._capacity_freed    # event consumed
    assert set(strat._pending) == set(before)
    for k, p in strat._pending.items():
        assert p.decision.t_done <= before[k] + 1e-9
    t_next, _ = strat.step(0.0)         # and the server keeps serving
    assert t_next is not None


def test_async_strategy_readmit_schedule_no_later():
    """_AsyncStar under re-admission: the schedule-level guarantee,
    checked without any JAX training by comparing the *planned* upload
    queues of two AsyncFLEO-style pricing passes — re-admission never
    delays the round and never delays any single upload (per-entry
    monotone adoption)."""
    from benchmarks.common import make_comms_env, price_async_round
    from repro.core.engine import SimConfig

    sim = SimConfig(
        constellation=ConstellationConfig(num_planes=3, sats_per_plane=6),
        horizon_hours=24.0,
    )
    base = make_comms_env(sim)
    r_base, m_base, _ = price_async_round(
        base.derive(ledger=GSResourceLedger(1, 1)), payload_bits=PAYLOAD,
        train_time_s=300.0, readmit=False,
    )
    r_re, m_re, _ = price_async_round(
        base.derive(ledger=GSResourceLedger(1, 1)), payload_bits=PAYLOAD,
        train_time_s=300.0, readmit=True,
    )
    assert r_base is not None and r_re is not None
    assert r_re <= r_base + 1e-9
    assert m_re <= m_base + 1e-9
