"""Orbital-mechanics substrate tests (unit + hypothesis properties)."""
import math

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.orbits import (
    ConstellationConfig,
    GroundStation,
    VisibilityPredictor,
    WalkerDelta,
    elevation_angle,
    orbital_period,
    orbital_speed,
    visibility_mask,
    visibility_windows,
)
from repro.orbits.constellation import R_EARTH


def test_paper_constants():
    # paper §V-A: 1500 km altitude LEO; period ~116 min, speed ~7.1 km/s
    cfg = ConstellationConfig()
    assert cfg.num_satellites == 40
    assert 110 * 60 < cfg.period_s < 120 * 60
    assert 7000 < cfg.speed_ms < 7300


@given(st.floats(min_value=300e3, max_value=2000e3))
def test_speed_period_consistency(h):
    # v * T == orbit circumference
    v, T = orbital_speed(h), orbital_period(h)
    circumference = 2 * math.pi * (R_EARTH + h)
    assert abs(v * T - circumference) / circumference < 1e-9


@given(st.floats(min_value=0, max_value=86400.0))
def test_satellite_radius_constant(t):
    w = WalkerDelta(ConstellationConfig())
    pos = w.positions(np.asarray([t]))
    radii = np.linalg.norm(pos, axis=-1)
    np.testing.assert_allclose(radii, w.radius, rtol=1e-9)


def test_positions_periodic():
    cfg = ConstellationConfig()
    w = WalkerDelta(cfg)
    p0 = w.positions(np.asarray([0.0]))
    p1 = w.positions(np.asarray([cfg.period_s]))
    np.testing.assert_allclose(p0, p1, atol=1e-3)


def test_equal_spacing_on_plane():
    cfg = ConstellationConfig()
    w = WalkerDelta(cfg)
    pos = w.positions(np.asarray([123.0]))[0, :, 0]  # plane 0
    # consecutive-slot chord lengths all equal
    chords = [
        np.linalg.norm(pos[i] - pos[(i + 1) % cfg.sats_per_plane])
        for i in range(cfg.sats_per_plane)
    ]
    np.testing.assert_allclose(chords, chords[0], rtol=1e-9)
    np.testing.assert_allclose(chords[0], w.isl_length_m(), rtol=1e-9)


def test_gs_rotates_with_earth():
    gs = GroundStation()
    day = 86164.0905  # sidereal day
    p0 = gs.eci(np.asarray([0.0]))
    p1 = gs.eci(np.asarray([day]))
    np.testing.assert_allclose(p0, p1, atol=1.0)


def test_elevation_at_zenith():
    gs = GroundStation(lat_deg=0.0, lon_deg=0.0, alt_m=0.0)
    r_g = gs.eci(np.asarray([0.0]))[0]
    r_s = r_g * (1.0 + 1500e3 / np.linalg.norm(r_g))
    el = elevation_angle(r_s, r_g)
    assert abs(el - math.pi / 2) < 1e-6


def test_windows_match_mask():
    cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
    w = WalkerDelta(cfg)
    gs = GroundStation()
    t = np.arange(0, 6 * 3600, 10.0)
    mask = visibility_mask(w, gs, t)
    wins = visibility_windows(w, gs, 0, 6 * 3600, coarse_step_s=10.0,
                              refine=False)
    # every window interior grid point must be visible per the mask
    for win in wins:
        i0 = int(win.t_start // 10) + 1
        i1 = int(win.t_end // 10) - 1
        if i1 > i0:
            assert mask[win.plane, win.slot, i0:i1].all()


def test_windows_irregular_like_fig3():
    """Fig. 3: visits are irregular — durations and gaps vary."""
    cfg = ConstellationConfig(num_planes=4, sats_per_plane=4)
    w = WalkerDelta(cfg)
    gs = GroundStation()
    wins = visibility_windows(w, gs, 0, 18 * 3600)
    by_sat = {}
    for win in wins:
        by_sat.setdefault((win.plane, win.slot), []).append(win)
    gaps = []
    for sat_wins in by_sat.values():
        for a, b in zip(sat_wins, sat_wins[1:]):
            gaps.append(b.t_start - a.t_end)
    assert len(gaps) > 5
    assert np.std(gaps) > 0.1 * np.mean(gaps)  # genuinely irregular


def test_predictor_wait_time_and_duration_constraint():
    cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
    w = WalkerDelta(cfg)
    gs = GroundStation()
    pred = VisibilityPredictor(w, gs, horizon_s=24 * 3600)
    sat = w.satellites[0]
    wins = pred.windows_of(sat)
    assert wins, "satellite should visit within a day"
    t_mid = 0.5 * (wins[0].t_start + wins[0].t_end)
    assert pred.wait_time(sat, t_mid) == 0.0
    assert pred.current_window(sat, t_mid) is not None
    # a min_duration longer than every window must skip to None or a
    # window that genuinely satisfies it
    w_long = pred.next_window_with_duration(sat, 0.0, 1e7)
    assert w_long is None
    w_ok = pred.next_window_with_duration(sat, 0.0, 10.0)
    assert w_ok is not None and w_ok.duration >= 10.0
